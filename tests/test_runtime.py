"""The multi-process federation runtime: TCP transport framing, the
serial-schedule bit-identity acceptance against the in-memory executor,
checkpointed crash+rejoin recovery, and the arrival (async) schedule
under scripted faults.

Everything spawning real OS processes is marked ``runtime`` (and
``slow``): CI runs them in a dedicated job with a hard timeout and
orphan cleanup (`pytest -m runtime`).
"""
import socket

import numpy as np
import pytest

from repro.configs.base import RuntimeConfig
from repro.core.wire import SERVER, Message, RecordingChannel, party
from repro.runtime import (FailurePlan, PartyFault, TransportTimeout,
                           FramedSocket, history_losses, run_federation,
                           run_reference)

runtime = pytest.mark.runtime
slow = pytest.mark.slow


def _spec(**vfl):
    base = {"mu": 1e-3, "lr_party": 1e-2, "lr_server": 1e-3}
    base.update(vfl)
    return {"kind": "lr", "parties": 2, "features": 16, "samples": 64,
            "batch": 8, "seed": 0, "vfl": base}


def _cfg(**kw):
    kw.setdefault("deadline_s", 120.0)
    return RuntimeConfig(**kw)


# ------------------------------------------------------- framing (no mp) --

def _socketpair():
    a, b = socket.socketpair()
    return FramedSocket(a), FramedSocket(b)


def test_framed_socket_roundtrips_messages_and_controls():
    a, b = _socketpair()
    msg = Message.make("c_up", party(0), SERVER, 2,
                       np.arange(6, dtype=np.float32),
                       meta={"idx": np.arange(6), "dir": 0})
    a.send_message(msg)
    a.send_control({"type": "ping"})
    kind, got = b.recv(timeout=5.0)
    assert kind == "msg"
    assert (got.kind, got.sender, got.round, got.nbytes) == \
        ("c_up", party(0), 2, 24)
    np.testing.assert_array_equal(got.payload, msg.payload)
    np.testing.assert_array_equal(got.meta["idx"], msg.meta["idx"])
    kind, got = b.recv(timeout=5.0)
    assert kind == "ctl" and got == {"type": "ping"}
    # measured socket bytes cover framing overhead on top of the payload
    assert a.bytes_out == b.bytes_in > msg.nbytes
    a.close(), b.close()


def test_framed_socket_timeout_is_typed():
    a, b = _socketpair()
    with pytest.raises(TransportTimeout):
        b.recv(timeout=0.05)
    a.close(), b.close()


def test_recv_survives_mid_frame_timeout():
    """A timeout with a frame partially received must not desynchronize
    the stream: the retried recv() resumes the SAME frame."""
    from repro.runtime.transport import encode_message
    a, b = _socketpair()
    msg = Message.make("c_up", party(0), SERVER, 0,
                       np.arange(16, dtype=np.float32))
    body = encode_message(msg)
    import struct
    frame = struct.pack(">I", len(body) + 1) + b"\x00" + body
    a.sock.sendall(frame[:11])                  # header + a few bytes
    with pytest.raises(TransportTimeout):
        b.recv(timeout=0.05)
    a.sock.sendall(frame[11:])                  # the rest arrives late
    kind, got = b.recv(timeout=5.0)
    assert kind == "msg"
    np.testing.assert_array_equal(got.payload, msg.payload)
    a.close(), b.close()


# ------------------------------------- acceptance: TCP == memory, bitwise --

@runtime
@slow
def test_tcp_run_bit_identical_to_inmemory_reference():
    """A fixed-seed 2-party run over the TCP transport reproduces the
    in-memory InMemoryChannel loss trajectory BIT-identically, and a
    RecordingChannel stacked on the TCP transport yields the same
    per-kind byte accounting and transcript as the simulated path."""
    spec, rounds = _spec(), 5
    res = run_federation(spec, rounds, cfg=_cfg(),
                         channel_kind="recording")
    rec = RecordingChannel()
    tr, ref = run_reference(spec, rounds, channel=rec)

    np.testing.assert_array_equal(
        history_losses(res), np.asarray([h for _, h in ref.history]))
    # wire accounting: channel counters AND recorded transcript agree
    # with the single-process path, kind by kind
    assert res["server"]["bytes_by_kind"] == dict(rec.bytes_by_kind)
    assert res["server"]["msgs_by_kind"] == dict(rec.msgs_by_kind)
    assert res["server"]["transcript_bytes_by_kind"] == \
        dict(rec.transcript.bytes_by_kind())
    assert res["server"]["transcript_len"] == len(rec.transcript)
    # every endpoint ends at the same parameters
    for m in range(2):
        np.testing.assert_array_equal(res["parties"][m]["final_w"]["w"],
                                      np.asarray(tr.party_w[m]["w"]))
    np.testing.assert_array_equal(res["server"]["w0"]["b"],
                                  np.asarray(tr.server.w0["b"]))
    # the actual socket bytes exceed payload bytes (framing overhead) but
    # every frame's payload span was validated against wire_nbytes
    total_payload = sum(res["server"]["bytes_by_kind"].values())
    assert res["server"]["socket_bytes_in"] > 0
    assert (res["server"]["socket_bytes_in"]
            + res["server"]["socket_bytes_out"]) > total_payload


@runtime
@slow
def test_int8_codec_rides_the_tcp_transport():
    spec, rounds = _spec(codec="int8"), 3
    res = run_federation(spec, rounds, cfg=_cfg())
    _, ref = run_reference(spec, rounds)
    np.testing.assert_array_equal(
        history_losses(res), np.asarray([h for _, h in ref.history]))
    # int8 wire: (batch + 4 scale) bytes per c payload
    assert res["server"]["bytes_by_kind"]["c_up"] == rounds * 2 * (8 + 4)


# ------------------------------------------- crash + checkpointed rejoin --

@runtime
@slow
def test_party_crash_rejoin_resumes_losslessly(tmp_path):
    """Scripted crash at round 3 + delayed rejoin: the rejoined party
    restores from its latest checkpoint, replays its RNG, and the
    federation reproduces the no-fault trajectory bit-for-bit (the
    paper's losslessness claim, across a real process boundary)."""
    spec, rounds = _spec(lr_party=5e-2, lr_server=1e-2), 6
    ok = run_federation(spec, rounds, cfg=_cfg(),
                        ckpt_root=str(tmp_path / "ok"))
    plan = FailurePlan({1: PartyFault(crash_at_round=3,
                                      rejoin_delay_s=0.3)})
    crashed = run_federation(spec, rounds, cfg=_cfg(), plan=plan,
                             ckpt_root=str(tmp_path / "crash"))
    assert crashed["rejoins"] == 1
    assert crashed["server"]["disconnects"] == 1
    np.testing.assert_array_equal(history_losses(ok),
                                  history_losses(crashed))
    for m in range(2):
        np.testing.assert_array_equal(ok["parties"][m]["final_w"]["w"],
                                      crashed["parties"][m]["final_w"]["w"])
    # the membership change snapshotted server state through
    # repro.checkpoint (plus the final run-complete snapshot)
    from repro.checkpoint import latest_step, load_metadata
    step = latest_step(str(tmp_path / "crash" / "server"))
    assert step == crashed["server"]["updates"]
    assert load_metadata(str(tmp_path / "crash" / "server"),
                         step)["updates"] == step
    # the crashed party resumed from its own checkpoint dir
    assert latest_step(str(tmp_path / "crash" / "party1")) == rounds


@runtime
@slow
def test_federation_stop_and_resume_is_bitwise_continuous(tmp_path):
    """Elastic resume of the WHOLE federation: run 3 rounds with
    checkpointing, restart every process with resume=True for 6, and
    the stitched trajectory equals one uninterrupted 6-round run
    bit-for-bit (server restores w0/c_table/update-count + reply cache,
    parties restore their blocks and fast-forward their RNG streams)."""
    spec = _spec()
    cont = run_federation(spec, 6, cfg=_cfg())
    root = str(tmp_path / "ck")
    first = run_federation(spec, 3, cfg=_cfg(), ckpt_root=root)
    second = run_federation(spec, 6, cfg=_cfg(), ckpt_root=root,
                            resume=True)
    stitched = np.concatenate([history_losses(first),
                               history_losses(second)])
    np.testing.assert_array_equal(stitched, history_losses(cont))
    for m in range(2):
        np.testing.assert_array_equal(
            cont["parties"][m]["final_w"]["w"],
            second["parties"][m]["final_w"]["w"])


@runtime
@slow
def test_resume_replays_rounds_behind_server_from_persisted_cache(tmp_path):
    """A party whose checkpoint lags the server's progress (here: its
    newest checkpoint is deleted between runs, standing in for a kill
    inside the process-round/checkpoint window) replays an
    already-processed round on resume; the server answers it from the
    PERSISTED reply cache without advancing state, and the stitched
    trajectory still equals the uninterrupted run."""
    import os

    spec = _spec()
    cont = run_federation(spec, 5, cfg=_cfg())
    root = str(tmp_path / "ck")
    first = run_federation(spec, 3, cfg=_cfg(), ckpt_root=root)
    for m in range(2):                   # drop every party's newest step
        for suffix in ("npz", "json"):
            os.remove(os.path.join(root, f"party{m}",
                                   f"step_00000003.{suffix}"))
    second = run_federation(spec, 5, cfg=_cfg(), ckpt_root=root,
                            resume=True)
    # the replayed round 2 is answered from cache: history gains only
    # the NEW rounds (3, 4 per party), not the replay
    stitched = np.concatenate([history_losses(first),
                               history_losses(second)])
    np.testing.assert_array_equal(stitched, history_losses(cont))
    for m in range(2):
        np.testing.assert_array_equal(
            cont["parties"][m]["final_w"]["w"],
            second["parties"][m]["final_w"]["w"])


@runtime
@slow
def test_resume_rewinds_party_when_server_snapshot_lags(tmp_path):
    """The OTHER hard-kill window: the server's newest snapshot is gone
    (stands in for a kill before the cadence snapshot landed) while the
    parties checkpointed further. On resume the welcome handshake tells
    each party the server's restored progress, the party REWINDS to it,
    and the lost rounds re-execute deterministically — the re-run
    entries and the continuation both match the uninterrupted run."""
    import os

    from repro.checkpoint import available_steps

    spec = _spec()
    cont = run_federation(spec, 5, cfg=_cfg())
    root = str(tmp_path / "ck")
    run_federation(spec, 3, cfg=_cfg(), ckpt_root=root)
    server_dir = os.path.join(root, "server")
    steps = available_steps(server_dir)
    assert len(steps) > 1                # cadence snapshots exist
    for suffix in ("npz", "json"):       # drop the newest server snapshot
        os.remove(os.path.join(server_dir, f"step_{steps[-1]:08d}.{suffix}"))
    restored_updates = available_steps(server_dir)[-1]
    second = run_federation(spec, 5, cfg=_cfg(), ckpt_root=root,
                            resume=True)
    # the resumed run re-executes the lost updates then continues: its
    # history is exactly the uninterrupted run's tail from the restored
    # update count onward
    np.testing.assert_array_equal(history_losses(second),
                                  history_losses(cont)[restored_updates:])
    for m in range(2):
        np.testing.assert_array_equal(
            cont["parties"][m]["final_w"]["w"],
            second["parties"][m]["final_w"]["w"])


@runtime
@slow
def test_arrival_schedule_enforces_tau_staleness_bound():
    """Assumption 4 ENFORCED: with a slow-link straggler the fast party
    would race arbitrarily far ahead under plain arrival dispatch;
    ``max_staleness=1`` parks its rounds until the laggard catches up.
    The server reports both the parking events (proof the bound engaged)
    and the maximum staleness actually admitted (never above tau)."""
    spec, rounds = _spec(), 5
    plan = FailurePlan({1: PartyFault(slow_send_s=0.25)})
    res = run_federation(spec, rounds, plan=plan,
                         cfg=_cfg(schedule="arrival", max_staleness=1))
    srv = res["server"]
    assert srv["parked"] > 0                  # the fast party got parked
    assert srv["staleness_max"] <= 1          # tau held for every round
    assert srv["processed"] == [rounds, rounds]
    assert srv["updates"] == 2 * rounds
    h = history_losses(res)
    assert len(h) == 2 * rounds and np.isfinite(h).all()


@runtime
@slow
def test_arrival_schedule_tolerates_crash_and_straggler():
    """AsyREVEL's async dispatch on the real transport: a crash+rejoin
    and a slow-link straggler; every party still completes its budget
    and the trajectory stays finite."""
    spec, rounds = _spec(), 5
    plan = FailurePlan({0: PartyFault(crash_at_round=2, rejoin_delay_s=0.3),
                        1: PartyFault(slow_send_s=0.05)})
    res = run_federation(spec, rounds, plan=plan,
                         cfg=_cfg(schedule="arrival"))
    assert res["server"]["processed"] == [rounds, rounds]
    assert res["server"]["updates"] == 2 * rounds
    h = history_losses(res)
    assert len(h) == 2 * rounds and np.isfinite(h).all()
