"""Per-architecture smoke tests: REDUCED variants (2 layers, d_model<=256,
<=4 experts) run one forward/train step and one decode step on CPU,
asserting output shapes and no NaNs. The FULL configs are exercised only by
launch/dryrun.py (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import steps as step_lib
from repro.models import build_model

pytestmark = pytest.mark.slow  # full model builds/compiles; fast CI skips


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_frames, cfg.d_model)).astype(np.float32))
    if cfg.frontend == "vq_stub":
        batch["modality_mask"] = jnp.asarray(
            (rng.random((B, S)) < 0.3).astype(np.int32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    state = step_lib.make_train_state(model, jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.forward(state.params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    train_step = jax.jit(step_lib.make_train_step(model))
    new_state, (loss, metrics) = train_step(state, batch)
    assert jnp.isfinite(loss)
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B = 2
    frames = None
    if cfg.enc_dec:
        frames = jnp.ones((B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    cache = model.init_cache(params, B, max_len=32, frames=frames)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["deepseek-7b", "rwkv6-1.6b", "hymba-1.5b",
                                  "whisper-small", "chameleon-34b",
                                  "qwen1.5-0.5b", "minicpm-2b", "yi-34b"])
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token must reproduce the full-sequence logits."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:    # rule out expert-capacity drops
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.key(3), (B, cfg.encoder_frames, cfg.d_model))
    if cfg.frontend == "vq_stub":
        batch["modality_mask"] = jnp.zeros((B, S), jnp.int32)
    full, _ = model.forward(params, batch)
    cache = model.init_cache(params, B, 16, frames=batch.get("frames"))
    outs = []
    for pos in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, pos:pos + 1],
                                      jnp.int32(pos))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=2e-4, rtol=2e-4)


def test_sliding_window_matches_full_for_short_seq():
    """Window >= seq must equal full attention exactly."""
    cfg = get_config("deepseek-7b", reduced=True)
    model_full = build_model(cfg)
    model_win = build_model(cfg.replace(sliding_window=64))
    params = model_full.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    a, _ = model_full.forward(params, batch)
    b, _ = model_win.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=2e-4)


def test_num_params_analytic_close_to_actual():
    """ModelConfig.num_params (roofline napkin math) tracks real counts."""
    from repro.utils.trees import tree_size
    for arch in ("qwen1.5-0.5b", "deepseek-7b", "rwkv6-1.6b"):
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.key(0))
        actual = tree_size(params)
        est = cfg.num_params()
        assert 0.5 < est / actual < 2.0, (arch, est, actual)
