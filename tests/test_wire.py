"""The wire subsystem: typed messages, channel accounting/clocks, transcript
recording + replay determinism, the InMemoryChannel bit-identity regression
against the pre-wire executor, and the transcript-driven privacy attacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import NETWORK_PROFILES, PaperLRConfig, VFLConfig
from repro.core import comms, privacy, wire
from repro.core.async_host import HostAsyncTrainer
from repro.core.tig import BlackBoxError, HostTIGTrainer
from repro.core.vfl import PaperLRModel, pad_features
from repro.core.wire import (SERVER, InMemoryChannel, Message,
                             NetworkChannel, RecordingChannel,
                             ReplayChannel, Transcript, party)


def _lr_setup(q=4, d=16, n=128, seed=0):
    model = PaperLRModel(PaperLRConfig(num_features=d, num_parties=q))
    key = jax.random.key(seed)
    X = jax.random.normal(key, (n, d))
    y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (n,)))
    return model, pad_features(X, d, q), np.asarray(y)


def _trainer(codec="f32", K=1, channel=None, seed=0, q=4, batch=8):
    model, X, y = _lr_setup(q=q)
    vfl = VFLConfig(num_parties=q, mu=1e-3, lr_party=1e-2, lr_server=1e-3,
                    codec=codec, num_directions=K)
    return HostAsyncTrainer(model, vfl, np.asarray(X), y, batch_size=batch,
                            compute_cost_s=0.0, seed=seed, channel=channel)


# ------------------------------------------------------------- messages ---

def test_message_kind_validated():
    with pytest.raises(ValueError):
        Message.make("grad_up", party(0), SERVER, 0, np.zeros(3))


def test_message_nbytes_measured_from_payload():
    msg = Message.make("c_up", party(1), SERVER, 0,
                       np.zeros((8,), np.float32))
    assert msg.nbytes == 32
    # int8 wire tuple: values + f32 scale
    msg = Message.make("c_up", party(1), SERVER, 0,
                       (np.zeros((8,), np.int8), np.float32(1.0)))
    assert msg.nbytes == 12
    # loss_down scalars are f32 on the wire regardless of python floats
    msg = Message.make("loss_down", SERVER, party(1), 0, (0.1, 0.2, 0.3))
    assert msg.nbytes == 12


def test_party_endpoint_roundtrip():
    assert wire.party_index(party(3)) == 3
    with pytest.raises(ValueError):
        wire.party_index(SERVER)


# ------------------------------------------- runtime wire codec (PR 4) ----

def _codec_payload(codec, shape=(8,), key=None):
    """A realistic encoded up-link payload for each codec."""
    from repro.core.exchange import get_codec
    c = jnp.arange(1, 1 + int(np.prod(shape)),
                   dtype=jnp.float32).reshape(shape) / 7.0
    wire = get_codec(codec).encode(c, key)
    return jax.tree.map(np.asarray, wire)


@pytest.mark.parametrize("codec", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("kind", list(wire.KINDS))
def test_wire_codec_roundtrip_every_kind_and_codec(codec, kind):
    """Satellite: every Message kind x payload codec encodes/decodes
    byte-identically through the runtime's versioned wire codec, and the
    decoded nbytes equals wire_nbytes of the original payload."""
    from repro.core.exchange import wire_nbytes
    from repro.runtime.transport import decode_message, encode_message
    if kind == "loss_down":
        payload = (0.5, 0.25, float(np.float32(1 / 3)))
    elif kind in ("grad_down", "param_down"):
        payload = np.linspace(-1, 1, 12, dtype=np.float32)
    else:
        payload = _codec_payload(codec, key=jax.random.key(0))
    sender, receiver = ((party(1), SERVER) if kind in wire.UP_KINDS
                        else (SERVER, party(1)))
    msg = Message.make(kind, sender, receiver, 9, payload,
                       meta={"idx": np.arange(4), "dir": 2})
    buf = encode_message(msg)
    assert encode_message(msg) == buf            # deterministic bytes
    got = decode_message(buf)
    assert (got.kind, got.sender, got.receiver, got.round) == \
        (kind, sender, receiver, 9)
    assert got.nbytes == msg.nbytes
    if kind == "loss_down":
        assert got.nbytes == 3 * 4
        assert got.scalars() == msg.scalars()    # f32-exact scalars
    else:
        assert got.nbytes == wire_nbytes(payload)
        la = [np.asarray(x) for x in jax.tree.leaves(payload)]
        lb = [np.asarray(x) for x in jax.tree.leaves(got.payload)]
        for a, b in zip(la, lb):
            assert a.dtype == b.dtype
            assert a.tobytes() == b.tobytes()    # byte-identical
    np.testing.assert_array_equal(got.meta["idx"], msg.meta["idx"])
    assert got.meta["dir"] == 2


def test_wire_codec_rejects_nbytes_mismatch():
    """The measured-bytes contract is VALIDATED at the socket: a message
    whose declared nbytes disagrees with the payload bytes that would
    hit the wire refuses to encode, and a tampered frame refuses to
    decode."""
    from repro.runtime.transport import (WireFormatError, decode_message,
                                         encode_message)
    bad = Message("c_up", party(0), SERVER, 0, np.zeros(4, np.float32),
                  nbytes=99, meta=None)
    with pytest.raises(WireFormatError):
        encode_message(bad)
    good = encode_message(Message.make(
        "c_up", party(0), SERVER, 0, np.zeros(4, np.float32)))
    with pytest.raises(WireFormatError):
        decode_message(b"XX" + good[2:])         # bad magic
    with pytest.raises(WireFormatError):
        decode_message(good[:2] + b"\x07" + good[3:])   # bad version


# ----------------------------------------------------------- transcript ---

def test_transcript_views_are_what_each_endpoint_observes():
    t = Transcript()
    for rnd in range(2):
        for m in (0, 1):
            t.append(Message.make("c_up", party(m), SERVER, rnd,
                                  np.zeros(4, np.float32)))
            t.append(Message.make("loss_down", SERVER, party(m), rnd,
                                  (0.5, 0.6)))
    # a curious party sees only its own links — 4 of the 8 messages
    v0 = t.view(party(0))
    assert len(v0) == 4
    assert all(party(0) in (m.sender, m.receiver) for m in v0)
    # the server sees everything here (it is on every link)
    assert len(t.view(SERVER)) == 8
    # colluding parties pool views without duplicating shared messages
    assert len(t.pooled_view([party(0), party(1)])) == 8
    assert t.kinds() == {"c_up", "loss_down"}
    assert t.bytes_by_kind() == {"c_up": 4 * 16, "loss_down": 4 * 8}


# ------------------------------------------- bit-identity regression ------

# Fingerprints of the PRE-WIRE HostAsyncTrainer (commit 5a5f89c) on the
# deterministic serial schedule: 6 rounds x 4 parties, _lr_setup data,
# batch 8, seed 0. The InMemoryChannel refactor must reproduce these
# byte-for-byte — the wire layer is transport, not math.
_PINNED = {
    "f32": ("5407e0830c51e2edc0daeee7f40a2f56", 1.1087950042565353e-05,
            1536, 192),
    "int8": ("eccf1ad4a8310a0d1b5d476a53f4dce5", 1.128053008869756e-05,
             576, 192),
}


@pytest.mark.parametrize("codec", ["f32", "int8"])
def test_inmemory_channel_bit_identical_to_prewire_executor(codec):
    import hashlib
    tr = _trainer(codec=codec)
    res = tr.run_serial(rounds=6)
    blob = b"".join(np.asarray(w["w"], np.float32).tobytes()
                    for w in tr.party_w)
    md5, w0_b, up, down = _PINNED[codec]
    assert hashlib.md5(blob).hexdigest() == md5
    assert float(np.asarray(tr.server.w0["b"])) == w0_b
    assert (res.bytes_up, res.bytes_down) == (up, down)


# -------------------------------------------------- record + replay -------

def test_recording_run_and_replay_bitwise_identical():
    """Wire-layer determinism: a recorded run and its replay (same seed,
    ReplayChannel verifying every message against the transcript) produce
    bitwise-identical party/server params and byte counts."""
    rec = RecordingChannel()
    tr1 = _trainer(codec="int8", channel=rec)
    res1 = tr1.run_serial(rounds=4)

    rep = ReplayChannel(rec.transcript)
    tr2 = _trainer(codec="int8", channel=rep)
    res2 = tr2.run_serial(rounds=4)

    assert rep.exhausted()
    for a, b in zip(tr1.party_w, tr2.party_w):
        np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    np.testing.assert_array_equal(np.asarray(tr1.server.w0["b"]),
                                  np.asarray(tr2.server.w0["b"]))
    assert (res1.bytes_up, res1.bytes_down) == \
        (res2.bytes_up, res2.bytes_down)
    assert rep.bytes_by_kind == rec.transcript.bytes_by_kind()


def test_replay_detects_divergent_traffic():
    rec = RecordingChannel()
    tr1 = _trainer(channel=rec)
    tr1.run_serial(rounds=2)
    rep = ReplayChannel(rec.transcript)
    tr2 = _trainer(channel=rep, seed=1)      # different seed -> different
    with pytest.raises(AssertionError):      # batches/payloads on the wire
        tr2.run_serial(rounds=2)


# ------------------------------------------------- channel accounting -----

@pytest.mark.parametrize("codec", ["f32", "bf16", "int8"])
def test_network_channel_accounting_agrees_with_meter(codec):
    """The three-way byte agreement for every codec: channel per-kind
    counters == exchange CommsMeter == analytic PRCO."""
    ch = NetworkChannel(NETWORK_PROFILES["lan"])
    tr = _trainer(codec=codec, channel=ch)
    res = tr.run_serial(rounds=3)
    assert ch.up_bytes == res.bytes_up
    assert ch.down_bytes == res.bytes_down
    comms.validate_channel(ch, res.updates, batch=8, codec=codec)
    comms.validate_measured(
        comms.RoundComms(ch.up_bytes // res.updates,
                         ch.down_bytes // res.updates), 8, codec=codec)
    assert ch.time_s > 0


def test_host_k_directions_down_link_accounting():
    """K>1 on the host executor: (1+K) up-link payloads and (1+K) down
    scalars per round, agreeing across channel, meter, and analytic."""
    K = 3
    ch = NetworkChannel(NETWORK_PROFILES["lan"])
    tr = _trainer(K=K, channel=ch)
    res = tr.run_serial(rounds=2)
    an = comms.zoo_vfl_round(8, codec="f32", num_directions=K)
    assert res.bytes_down == res.updates * an.down_bytes == \
        res.updates * (1 + K) * 4
    assert res.bytes_up == res.updates * an.up_bytes
    comms.validate_channel(ch, res.updates, batch=8, num_directions=K)
    assert ch.msgs_by_kind["c_hat_up"] == K * res.updates
    losses = [h for _, h in res.history]
    assert np.isfinite(losses).all()


def test_network_clock_prices_messages():
    cfg = NETWORK_PROFILES["lan"]
    ch = NetworkChannel(cfg)
    msg = Message.make("c_up", party(0), SERVER, 0,
                       np.zeros(1000, np.float32))
    ch.send(msg)
    expect = cfg.latency_s + 4000 / cfg.bandwidth_Bps
    assert ch.time_s == pytest.approx(expect)
    # straggler profile: party 0's link pays the multiplier, party 1's not
    ch2 = NetworkChannel(NETWORK_PROFILES["straggler"])
    ch2.send(Message.make("c_up", party(0), SERVER, 0,
                          np.zeros(1000, np.float32)))
    t0 = ch2.time_s
    ch2.send(Message.make("c_up", party(1), SERVER, 0,
                          np.zeros(1000, np.float32)))
    assert t0 == pytest.approx(6.0 * (ch2.time_s - t0))


def test_network_jitter_deterministic_per_seed():
    cfg = NETWORK_PROFILES["wan"]
    def clock(seed):
        ch = NetworkChannel(cfg, seed=seed)
        for r in range(5):
            ch.send(Message.make("c_up", party(0), SERVER, r,
                                 np.zeros(64, np.float32)))
        return ch.time_s
    assert clock(0) == clock(0)
    assert clock(0) != clock(1)


def test_measured_table3_ratio_within_5pct_of_analytic():
    """Acceptance: paper_ratio reproduced by measured channel time."""
    for d_l in (12, 16, 37, 98, 250, 5904):
        analytic = comms.paper_ratio(d_l, batch=1)
        measured = comms.measured_paper_ratio(d_l, batch=1)
        assert abs(measured - analytic) / analytic < 0.05, d_l


# --------------------------------------- transcripts drive the attacks ----

def _recorded_pair(rounds=10, batch=16):
    """Same data + seed through both frameworks; two transcripts."""
    model, X, y = _lr_setup(d=32, n=128)
    vfl = VFLConfig(num_parties=4, mu=1e-3, lr_party=5e-2,
                    lr_server=1e-2 / 4)
    rec_zoo, rec_tig = RecordingChannel(), RecordingChannel()
    HostAsyncTrainer(model, vfl, np.asarray(X), y, batch_size=batch,
                     compute_cost_s=0.0, seed=0,
                     channel=rec_zoo).run_serial(rounds=rounds)
    HostTIGTrainer(model, vfl, np.asarray(X), y, batch_size=batch, seed=0,
                   channel=rec_tig, sampler="full").run(rounds=rounds)
    return rec_zoo.transcript, rec_tig.transcript, y


def test_label_inference_from_recorded_transcripts():
    """The paper's Table-1 label-inference row, measured from executor
    traffic: ~1.0 accuracy off TIG's grad_down, ~chance off ZOO-VFL's
    loss_down — same data, same seeds."""
    t_zoo, t_tig, y = _recorded_pair()
    tig = privacy.label_inference_attack(t_tig, y, m=0)
    zoo = privacy.label_inference_attack(t_zoo, y, m=0)
    assert tig["observable"] == "grad_down"
    assert tig["accuracy"] == 1.0
    assert zoo["observable"] == "loss_down"
    assert abs(zoo["accuracy"] - 0.5) < 0.1


def test_rma_needs_grad_on_the_wire():
    t_zoo, t_tig, _ = _recorded_pair(rounds=4)
    rma_tig = privacy.reverse_multiplication_from_transcript(
        t_tig, eta=5e-2, colluders=(0, 1))
    assert rma_tig["feasible"] and rma_tig["recovered"] is not None
    rma_zoo = privacy.reverse_multiplication_from_transcript(
        t_zoo, eta=5e-2, colluders=(0, 1))
    assert not rma_zoo["feasible"] and rma_zoo["recovered"] is None


def test_feature_inference_underdetermined_without_param_down():
    t_zoo, _, _ = _recorded_pair(rounds=4)
    fi = privacy.feature_inference_from_transcript(t_zoo, x_dim=8)
    assert not fi["params_leaked"]
    assert fi["ratio"] < 1.0 and not fi["solvable"]


def test_replay_backdoor_direction_control_by_observable():
    t_zoo, t_tig, _ = _recorded_pair(rounds=4)
    bd_tig = privacy.replay_backdoor_attack(t_tig, lr=5e-2, mu=1e-3,
                                            w_dim=4096)
    assert bd_tig["direction_control"]
    cos = np.mean([privacy.replay_backdoor_attack(
        t_zoo, lr=5e-2, mu=1e-3, w_dim=4096,
        key=jax.random.key(s))["cos_to_target"] for s in range(10)])
    assert cos < 0.05


def test_exposure_derived_from_observed_kinds():
    t_zoo, t_tig, _ = _recorded_pair(rounds=2)
    ex_zoo = privacy.exposure_from_transcript(t_zoo)
    assert not ex_zoo["intermediate_grads"] and not ex_zoo["model_params"]
    assert ex_zoo["function_values"]
    ex_tig = privacy.exposure_from_transcript(t_tig)
    assert ex_tig["intermediate_grads"] and not ex_tig["model_params"]


# ------------------------------------------------------ TIG host executor -

def test_host_tig_trainer_trains_and_refuses_black_box():
    model, X, y = _lr_setup(d=32, n=128)
    vfl = VFLConfig(num_parties=4, lr_party=5e-2, lr_server=1e-2)
    tr = HostTIGTrainer(model, vfl, np.asarray(X), y, batch_size=32,
                        seed=0)
    hist = tr.run(rounds=20)
    assert hist[-1] < hist[0]
    assert np.isfinite(hist).all()
    with pytest.raises(BlackBoxError):
        HostTIGTrainer(model, vfl, np.asarray(X), y, black_box=True)


def test_host_tig_byte_accounting_matches_tig_round():
    model, X, y = _lr_setup()
    vfl = VFLConfig(num_parties=4)
    ch = InMemoryChannel()
    tr = HostTIGTrainer(model, vfl, np.asarray(X), y, batch_size=16,
                        seed=0, channel=ch)
    tr.run(rounds=3)
    rounds = 3 * 4
    an = comms.tig_round(batch=16)
    assert ch.bytes_by_kind["c_up"] == rounds * an.up_bytes
    assert ch.bytes_by_kind["grad_down"] == rounds * an.down_bytes
    # + the 4-byte monitoring loss scalar per round
    assert ch.bytes_by_kind["loss_down"] == rounds * 4


def test_tig_step_respects_activation_probs():
    """Satellite: tig_step must sample the activated party from
    vfl.activation_probs (shared with AsyREVEL), not uniformly — with a
    point mass on party 0, the other parties' blocks never move."""
    from repro.core.tig import tig_train
    model, X, y = _lr_setup()
    data = {"x": X, "y": jnp.asarray(y)}
    vfl = VFLConfig(num_parties=4, lr_party=5e-2, lr_server=1e-2,
                    activation_probs=(1.0, 0.0, 0.0, 0.0))
    state, losses = tig_train(model, vfl, data, jax.random.key(0),
                              steps=20, batch_size=8)
    w = np.asarray(state.parties["w"])
    assert np.abs(w[0]).max() > 0            # party 0 trained
    np.testing.assert_array_equal(w[1:], 0)  # others never activated
